//! Differential proof that activity-gated stepping ([`SimMode::Gated`])
//! is cycle-accurately **byte-identical** to the dense reference sweep
//! ([`SimMode::Dense`]).
//!
//! Methodology (see `docs/performance.md`): the same seeded workload is
//! run to completion twice — once per [`SimMode`] — and every observable
//! counter in the system is serialized into one digest string: total
//! cycles, per-network flit-conservation counters, per-link
//! delivered/stall/busy counters, per-router-per-port forwarding
//! counters, per-node target statistics and per-tile generator
//! completions and latency aggregates. The two digests must be equal to
//! the byte. Any divergence — a component skipped while it had work, a
//! wake edge firing a cycle early or late — shows up as a counter
//! mismatch somewhere in this digest.
//!
//! The grid covers all three fabrics × three traffic patterns (uniform
//! random, tornado, nearest-neighbor), which together exercise XY mesh
//! routing, both directions of every wraparound link, wormhole bursts
//! across pipelined links, and long quiescent stretches between bursts.

use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::flit::NodeId;
use floonoc::noc::{NocConfig, NocSystem};
use floonoc::sim::SimMode;
use floonoc::topology::TopologyKind;
use floonoc::traffic::{GenCfg, Pattern};

/// 9-tile fabric of `kind` (3×3 for mesh/torus, 9-ring), mode selected.
fn fabric(kind: TopologyKind, mode: SimMode) -> NocSystem {
    NocSystem::new(NocConfig::fabric(kind, 3, 3).with_sim_mode(mode))
}

/// The differential workload: every tile runs seeded narrow traffic with
/// the pattern under test plus a few nearest-neighbor wide DMA bursts
/// (single-hop wide wormholes are deadlock-safe on wrap fabrics without
/// VCs — see docs/topologies.md). Bursty-with-gaps by construction: the
/// narrow generators finish at different times, leaving long quiescent
/// stretches that exercise the gating/pruning paths, not just saturation.
fn workload(kind: TopologyKind, pattern: Pattern, mode: SimMode) -> TiledWorkload {
    let sys = fabric(kind, mode);
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern,
                num_txns: 12,
                seed: 0xBEEF + i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 12)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::NearestNeighbor,
                num_txns: 3,
                burst_len: 7,
                seed: 0xD0A + i as u64,
                ..GenCfg::dma_burst(NodeId(0), 3, false)
            }),
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

/// Serialize every observable counter of a drained workload. Two runs
/// are equivalent iff their digests are byte-identical.
fn digest(w: &mut TiledWorkload) -> String {
    use std::fmt::Write;
    let mut d = String::new();
    writeln!(d, "cycles={}", w.sys.now).unwrap();
    for (n, c) in w.sys.counters.iter().enumerate() {
        writeln!(d, "net{n} injected={} ejected={}", c.injected, c.ejected).unwrap();
    }
    for (n, net) in w.sys.nets.iter().enumerate() {
        for (lid, l) in net.links.iter().enumerate() {
            // Skip never-touched links to keep the digest readable; a
            // link touched in one mode but not the other still diverges
            // (its line exists on one side only).
            if l.delivered == 0 && l.busy_cycles == 0 {
                continue;
            }
            writeln!(
                d,
                "net{n} link{lid} delivered={} stall={} busy={}",
                l.delivered, l.stall_cycles, l.busy_cycles
            )
            .unwrap();
        }
        for (rid, r) in net.routers.iter().enumerate() {
            if r.forwarded == 0 {
                continue;
            }
            let per_port: Vec<String> = (0..r.cfg.ports)
                .map(|p| r.forwarded_on(p).to_string())
                .collect();
            writeln!(
                d,
                "net{n} router{rid} forwarded={} active={} ports=[{}]",
                r.forwarded,
                r.active_cycles,
                per_port.join(",")
            )
            .unwrap();
        }
    }
    for (idx, node) in w.sys.nodes.iter().enumerate() {
        let s = &node.target.stats;
        writeln!(
            d,
            "node{idx} reads={} writes={} atomics={} req_stalls={}",
            s.reads_served, s.writes_served, s.atomics_served, s.req_stall_cycles
        )
        .unwrap();
    }
    for t in &mut w.tiles {
        for (tag, g) in [
            ("core", t.core_gen.as_mut()),
            ("dma", t.dma_gen.as_mut()),
        ] {
            let Some(g) = g else { continue };
            writeln!(
                d,
                "tile{} {tag} issued={} completed={} lat_count={} lat_mean={:.6} lat_min={} lat_max={} lat_p50={}",
                t.node.0,
                g.issued,
                g.completed,
                g.latencies.count(),
                g.latencies.mean(),
                g.latencies.min(),
                g.latencies.max(),
                g.latencies.p50(),
            )
            .unwrap();
        }
    }
    d
}

/// Run one (fabric, pattern, mode) cell to completion and digest it.
fn run_cell(kind: TopologyKind, pattern: Pattern, mode: SimMode) -> String {
    let mut w = workload(kind, pattern, mode);
    assert!(
        w.run_to_completion(2_000_000),
        "{kind:?}/{pattern:?}/{mode:?} must drain"
    );
    assert!(w.protocol_ok(), "{kind:?}/{pattern:?}/{mode:?} protocol clean");
    digest(&mut w)
}

fn assert_equivalent(kind: TopologyKind, pattern: Pattern) {
    let gated = run_cell(kind, pattern, SimMode::Gated);
    let dense = run_cell(kind, pattern, SimMode::Dense);
    assert!(
        gated == dense,
        "gated != dense for {kind:?}/{pattern:?}\n--- gated ---\n{gated}\n--- dense ---\n{dense}"
    );
}

const PATTERNS: [Pattern; 3] = [
    Pattern::UniformTiles,
    Pattern::Tornado,
    Pattern::NearestNeighbor,
];

#[test]
fn mesh_gated_equals_dense_across_patterns() {
    for p in PATTERNS {
        assert_equivalent(TopologyKind::Mesh, p);
    }
}

#[test]
fn torus_gated_equals_dense_across_patterns() {
    for p in PATTERNS {
        assert_equivalent(TopologyKind::Torus, p);
    }
}

#[test]
fn ring_gated_equals_dense_across_patterns() {
    for p in PATTERNS {
        assert_equivalent(TopologyKind::Ring, p);
    }
}

/// Wide-only baseline link configuration through the same differential
/// harness: the gating must be mode-agnostic (two networks, merged
/// response classes, W beats on the request net).
#[test]
fn wide_only_mode_gated_equals_dense() {
    let run = |mode: SimMode| {
        let sys = NocSystem::new(NocConfig::mesh(3, 3).wide_only().with_sim_mode(mode));
        let tiles = sys.topo.num_tiles;
        let profiles: Vec<TileTraffic> = (0..tiles)
            .map(|i| TileTraffic {
                core: Some(GenCfg {
                    pattern: Pattern::UniformTiles,
                    num_txns: 8,
                    seed: 0xFACE + i as u64,
                    ..GenCfg::narrow_probe(NodeId(0), 8)
                }),
                dma: Some(GenCfg {
                    pattern: Pattern::Neighbor,
                    num_txns: 2,
                    seed: 0xCAFE + i as u64,
                    write_fraction: 1.0,
                    ..GenCfg::dma_burst(NodeId(0), 2, true)
                }),
            })
            .collect();
        let mut w = TiledWorkload::new(sys, profiles);
        assert!(w.run_to_completion(2_000_000), "{mode:?} drains");
        assert!(w.protocol_ok());
        digest(&mut w)
    };
    let gated = run(SimMode::Gated);
    let dense = run(SimMode::Dense);
    assert!(gated == dense, "wide-only gated != dense\n{gated}\n---\n{dense}");
}

/// Pipelined multi-stage links under gating: with deeper output
/// pipelines (buffer islands on long routing channels) a flit spends
/// several cycles in stages where *only* the link occupancy — not any
/// router input — proves the network busy. If the active set dropped
/// those links, the flit would strand and the run would time out; the
/// digest equality additionally pins exact timing.
#[test]
fn pipelined_links_gated_equals_dense() {
    let run = |mode: SimMode| {
        let mut cfg = NocConfig::mesh(3, 1).with_sim_mode(mode);
        cfg.in_buf_depth = 1; // tight buffers: maximum backpressure
        let sys = NocSystem::new(cfg);
        let profiles = vec![
            TileTraffic {
                core: Some(GenCfg {
                    pattern: Pattern::FixedDst(NodeId(2)),
                    ..GenCfg::narrow_probe(NodeId(2), 6)
                }),
                dma: Some(GenCfg::dma_burst(NodeId(2), 2, false)),
            },
            TileTraffic::idle(),
            TileTraffic::idle(),
        ];
        let mut w = TiledWorkload::new(sys, profiles);
        assert!(w.run_to_completion(200_000), "{mode:?} drains");
        digest(&mut w)
    };
    let gated = run(SimMode::Gated);
    let dense = run(SimMode::Dense);
    assert!(gated == dense, "pipelined gated != dense\n{gated}\n---\n{dense}");
}
