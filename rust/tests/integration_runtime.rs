//! PJRT runtime integration: requires `make artifacts` (skips gracefully
//! when the artifacts are absent so `cargo test` works pre-build, but CI
//! and `make test` always build artifacts first).

use floonoc::compute::{host_matmul, max_abs_diff, TileCompute};
use floonoc::dse;
use floonoc::runtime::Runtime;
use floonoc::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::new("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime tests (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn meta_contract() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.meta.tile_dim, 64);
    assert_eq!(rt.meta.dse_mesh_n, 4);
    assert_eq!(rt.meta.entries.len(), 3);
    assert_eq!(rt.platform(), "cpu");
}

#[test]
fn tile_matmul_matches_host() {
    let Some(rt) = runtime() else { return };
    let tc = TileCompute::new(&rt).unwrap();
    let d = tc.dim;
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..d * d).map(|_| rng.f64() as f32 - 0.5).collect();
    let w: Vec<f32> = (0..d * d).map(|_| rng.f64() as f32 - 0.5).collect();
    let got = tc.matmul(&x, &w).unwrap();
    let want = host_matmul(&x, &w, d);
    let err = max_abs_diff(&got, &want);
    assert!(err < 1e-3, "PJRT result diverges from host: {err}");
}

#[test]
fn cluster_compute_applies_bias_relu() {
    let Some(rt) = runtime() else { return };
    let tc = TileCompute::new(&rt).unwrap();
    let d = tc.dim;
    let x = vec![0f32; d * d];
    let w = vec![0f32; d * d];
    // Zero matmul + bias: positive biases pass, negatives clamp to 0.
    let mut b = vec![0f32; d];
    b[0] = 2.5;
    b[1] = -3.0;
    let out = tc.cluster_compute(&x, &w, &b).unwrap();
    assert_eq!(out[0], 2.5);
    assert_eq!(out[1], 0.0);
}

#[test]
fn shape_contract_enforced() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("tile_matmul").unwrap();
    let bad = vec![0f32; 16];
    let err = exe.run_f32(&[(&bad, &[4, 4]), (&bad, &[4, 4])]);
    assert!(err.is_err(), "wrong shapes must be rejected");
}

#[test]
fn unknown_artifact_rejected() {
    let Some(rt) = runtime() else { return };
    assert!(rt.load("nonexistent").is_err());
}

#[test]
fn noc_perf_artifact_matches_native_model() {
    let Some(rt) = runtime() else { return };
    let n = rt.meta.dse_mesh_n;
    for (name, traffic) in [
        ("ring", dse::ring_traffic(n, 0.3)),
        ("uniform", dse::uniform_traffic(n, 0.7)),
    ] {
        let native = dse::link_loads(&traffic, n);
        let (art, art_max, art_mean, art_sat) =
            dse::artifact_link_loads(&rt, &traffic).unwrap();
        let mut diff = 0.0f64;
        for d in 0..4 {
            for y in 0..n {
                for x in 0..n {
                    diff = diff.max((art[d][y][x] - native[d][y][x]).abs());
                }
            }
        }
        assert!(diff < 1e-5, "{name}: Pallas artifact diverges by {diff}");
        assert!((art_max - dse::max_load(&native)).abs() < 1e-5);
        assert!((art_mean - dse::mean_load(&native)).abs() < 1e-5);
        assert!((art_sat - 1.0 / dse::max_load(&native)).abs() < 1e-3);
    }
}

#[test]
fn repeated_execution_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let tc = TileCompute::new(&rt).unwrap();
    let d = tc.dim;
    let mut rng = Rng::new(2);
    let x: Vec<f32> = (0..d * d).map(|_| rng.f64() as f32).collect();
    let w: Vec<f32> = (0..d * d).map(|_| rng.f64() as f32).collect();
    let a = tc.matmul(&x, &w).unwrap();
    let b = tc.matmul(&x, &w).unwrap();
    assert_eq!(a, b);
}
