//! Cross-module integration tests over the full simulated system.

use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::coordinator::{self as exp, zero_load_latency};
use floonoc::flit::NodeId;
use floonoc::noc::{LinkMode, NocConfig, NocSystem, NET_WIDE};
use floonoc::topology::MemEdge;
use floonoc::traffic::{GenCfg, Pattern};

/// §VI-A headline, through the public API.
#[test]
fn paper_zero_load_latency() {
    assert_eq!(zero_load_latency(LinkMode::NarrowWide), 18);
}

/// Zero-load in wide-only mode is the same (no contention, same routers).
#[test]
fn wide_only_zero_load_matches() {
    assert_eq!(zero_load_latency(LinkMode::WideOnly), 18);
}

/// Far-corner traffic on a large mesh: XY delivers over many hops with
/// latency growing by 4 cycles per extra hop pair (2-cycle routers,
/// request + response).
#[test]
fn latency_scales_with_hops() {
    let mut lat = Vec::new();
    for n in [2u8, 4] {
        let sys = NocSystem::new(NocConfig::mesh(n, n));
        let far = sys.topo.num_tiles as u16 - 1;
        let mut profiles: Vec<TileTraffic> =
            (0..sys.topo.num_tiles).map(|_| TileTraffic::idle()).collect();
        profiles[0].core = Some(GenCfg::narrow_probe(NodeId(far), 1));
        let mut w = TiledWorkload::new(sys, profiles);
        assert!(w.run_to_completion(10_000));
        lat.push(w.tiles[0].core_gen.as_mut().unwrap().latencies.max());
    }
    // 2x2: 2 hops each way; 4x4: 6 hops each way. 4 extra hop-pairs at
    // 2 cycles/router/direction = +16 cycles.
    assert_eq!(lat[1] - lat[0], 16, "{lat:?}");
}

/// Saturating all-to-all traffic drains without deadlock in both modes
/// and with protocol monitors clean — the core robustness statement.
#[test]
fn no_deadlock_under_saturation() {
    for mode in [LinkMode::NarrowWide, LinkMode::WideOnly] {
        let mut cfg = NocConfig::mesh(3, 3);
        cfg.mode = mode;
        let sys = NocSystem::new(cfg);
        let profiles: Vec<TileTraffic> = (0..9)
            .map(|i| TileTraffic {
                core: Some(GenCfg {
                    pattern: Pattern::UniformTiles,
                    max_outstanding: 16,
                    ids: 8,
                    seed: 1 + i as u64,
                    ..GenCfg::narrow_probe(NodeId(0), 40)
                }),
                dma: Some(GenCfg {
                    pattern: Pattern::UniformTiles,
                    max_outstanding: 8,
                    write_fraction: 0.5,
                    seed: 100 + i as u64,
                    ..GenCfg::dma_burst(NodeId(0), 10, false)
                }),
            })
            .collect();
        let mut w = TiledWorkload::new(sys, profiles);
        assert!(
            w.run_to_completion(2_000_000),
            "{mode:?} deadlocked or stalled"
        );
        assert!(w.protocol_ok(), "{mode:?} violated AXI ordering");
    }
}

/// Memory-controller traffic mixes with tile-to-tile traffic.
#[test]
fn boundary_mem_ctrl_traffic() {
    let sys = NocSystem::new(NocConfig::mesh(4, 2).with_mem_edge(MemEdge::EastWest));
    let profiles: Vec<TileTraffic> = (0..8)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                seed: i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 10)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::MemCtrls,
                write_fraction: 0.5,
                seed: 10 + i as u64,
                ..GenCfg::dma_burst(NodeId(0), 6, false)
            }),
        })
        .collect();
    let mut w = TiledWorkload::new(sys, profiles);
    assert!(w.run_to_completion(2_000_000));
    assert!(w.protocol_ok());
    // Memory controllers actually served wide traffic.
    let mems = w.sys.topo.mem_ctrls();
    let served: u64 = mems
        .iter()
        .map(|&m| {
            let t = &w.sys.nodes[m.0 as usize].target.stats;
            t.reads_served + t.writes_served
        })
        .sum();
    assert!(served > 0, "controllers served {served} ops");
}

/// The Fig. 5a experiment API: narrow-wide robust, wide-only degraded
/// (full sweep happens in benches; this is the 2-point sanity).
#[test]
fn fig5a_narrow_wide_beats_wide_only() {
    let nw = exp::fig5a(LinkMode::NarrowWide, false, &[0, 4]);
    let wo = exp::fig5a(LinkMode::WideOnly, false, &[0, 4]);
    assert!(nw[1].slowdown < wo[1].slowdown);
}

/// ROB flow control throttles but never wedges: a tiny ROB still
/// completes a long burst sequence.
#[test]
fn tiny_rob_completes() {
    let mut cfg = NocConfig::mesh(2, 1);
    cfg.wide_init.rob_slots = 16; // one 16-beat burst at a time
    let sys = NocSystem::new(cfg);
    let mut profiles: Vec<TileTraffic> = (0..2).map(|_| TileTraffic::idle()).collect();
    let mut c = GenCfg::dma_burst(NodeId(1), 12, false);
    c.max_outstanding = 8;
    profiles[0].dma = Some(c);
    let mut w = TiledWorkload::new(sys, profiles);
    assert!(w.run_to_completion(1_000_000));
    assert!(w.protocol_ok());
    assert_eq!(w.tiles[0].dma_gen.as_ref().unwrap().completed, 12);
}

/// Responses from different distances reorder in the network and the NI
/// must fix them up: reads alternate near/far destinations on one ID.
#[test]
fn reordering_exercised_and_corrected() {
    let sys = NocSystem::new(NocConfig::mesh(4, 1));
    let mut profiles: Vec<TileTraffic> = (0..4).map(|_| TileTraffic::idle()).collect();
    // One ID, alternating far (3 hops) and near (1 hop) reads: the near
    // response tends to arrive while the far one is outstanding.
    profiles[0].core = Some(GenCfg {
        pattern: Pattern::UniformTiles,
        ids: 1,
        max_outstanding: 4,
        seed: 42,
        ..GenCfg::narrow_probe(NodeId(1), 60)
    });
    let sys_has_buffered: bool;
    let mut w = TiledWorkload::new(sys, profiles);
    assert!(w.run_to_completion(1_000_000));
    assert!(w.protocol_ok(), "NI failed to restore same-ID order");
    let init = w.sys.nodes[0].narrow.as_ref().unwrap();
    let (bypassed, buffered) = init.reorder_stats();
    sys_has_buffered = buffered > 0;
    assert!(bypassed > 0, "in-order fast path never used");
    assert!(
        sys_has_buffered,
        "workload never exercised the ROB (adjust pattern)"
    );
}

/// Wide-only mode carries every payload class on two networks.
#[test]
fn wide_only_network_count() {
    let sys = NocSystem::new(NocConfig::mesh(2, 2).wide_only());
    assert_eq!(sys.nets.len(), 2);
    let sys = NocSystem::new(NocConfig::mesh(2, 2));
    assert_eq!(sys.nets.len(), 3);
}

/// Peak-bandwidth experiment sustains near line rate (§VI-B).
#[test]
fn peak_bandwidth_experiment() {
    let (util, gbps) = exp::peak_bandwidth(1.23);
    assert!(util > 0.8);
    assert!(gbps > 500.0 && gbps < 630.0);
}

/// Flit conservation: everything injected is eventually ejected.
#[test]
fn flit_conservation() {
    let sys = NocSystem::new(NocConfig::mesh(3, 3));
    let profiles: Vec<TileTraffic> = (0..9)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                seed: i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 25)
            }),
            dma: None,
        })
        .collect();
    let mut w = TiledWorkload::new(sys, profiles);
    assert!(w.run_to_completion(1_000_000));
    for c in &w.sys.counters {
        assert_eq!(c.injected, c.ejected, "flits lost or duplicated");
    }
}

/// Fig. 6b experiment API sanity (full values checked in unit tests).
#[test]
fn fig6b_runs() {
    let (p, pjb) = exp::fig6b_power();
    assert!(p.total_mw > 100.0);
    assert!(pjb > 0.1 && pjb < 0.3);
}
