//! Failure-injection and degenerate-configuration tests: the system must
//! either work correctly or fail loudly — never hang or silently corrupt.

use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::flit::NodeId;
use floonoc::ni::{Initiator, InitiatorCfg};
use floonoc::noc::{NocConfig, NocSystem};
use floonoc::topology::TILE_SPAN;
use floonoc::traffic::GenCfg;

/// Minimum-everything configuration still completes traffic.
#[test]
fn degenerate_minimum_config() {
    let mut cfg = NocConfig::mesh(2, 1);
    cfg.in_buf_depth = 1;
    cfg.output_reg = false;
    cfg.narrow_init.per_id_depth = 1;
    cfg.narrow_init.rob_slots = 1;
    cfg.wide_init.per_id_depth = 1;
    cfg.wide_init.rob_slots = 16;
    cfg.spm.mem_outstanding = 1;
    cfg.spm.pending_writes = 1;
    let sys = NocSystem::new(cfg);
    let mut profiles: Vec<TileTraffic> = (0..2).map(|_| TileTraffic::idle()).collect();
    profiles[0].core = Some(GenCfg {
        write_fraction: 0.5,
        seed: 5,
        ..GenCfg::narrow_probe(NodeId(1), 30)
    });
    profiles[0].dma = Some(GenCfg {
        write_fraction: 0.5,
        max_outstanding: 1,
        seed: 6,
        ..GenCfg::dma_burst(NodeId(1), 5, false)
    });
    let mut w = TiledWorkload::new(sys, profiles);
    assert!(w.run_to_completion(2_000_000), "degenerate config wedged");
    assert!(w.protocol_ok());
}

/// A 1×1 "mesh" (single tile, no links to anywhere) is constructible and
/// idles — boundary condition of the builder.
#[test]
fn single_tile_mesh_is_idle() {
    let mut sys = NocSystem::new(NocConfig::mesh(1, 1));
    assert!(sys.is_idle());
    sys.run(100);
    assert!(sys.is_idle());
}

/// Responses with bogus state are rejected loudly: handing the initiator
/// a response for a transaction it never issued panics (protocol
/// violation surfaced, not absorbed).
#[test]
#[should_panic(expected = "unknown rob_idx")]
fn spurious_response_panics() {
    use floonoc::axi::{BResp, Resp};
    use floonoc::flit::{FlooFlit, Header, Payload};
    let mut init = Initiator::new(InitiatorCfg::narrow_default(), NodeId(0));
    let bogus = FlooFlit::new(
        Header {
            dst: NodeId(0),
            src: NodeId(1),
            rob_idx: 3,
            rob_req: true,
            atomic: false,
            last: true,
        },
        Payload::NarrowB(BResp {
            id: 2,
            resp: Resp::Okay,
        }),
        0,
    );
    init.handle_response(&bogus);
}

/// Requests to unmapped addresses are caught at the generator/address-map
/// boundary (no silent misrouting): node_of_addr returns None.
#[test]
fn unmapped_address_detected() {
    let sys = NocSystem::new(NocConfig::mesh(2, 2));
    assert_eq!(sys.topo.node_of_addr(100 * TILE_SPAN), None);
    assert_eq!(
        sys.topo.node_of_addr(floonoc::topology::MEM_BASE),
        None,
        "no controllers configured"
    );
}

/// Extreme contention: 8 writers + 8 readers against one tile with a
/// tiny memory pipeline — must throttle, not deadlock.
#[test]
fn hotspot_contention_throttles() {
    let mut cfg = NocConfig::mesh(3, 3);
    cfg.spm.mem_outstanding = 2;
    let sys = NocSystem::new(cfg);
    let profiles: Vec<TileTraffic> = (0..9)
        .map(|i| {
            if i == 4 {
                TileTraffic::idle() // the victim hotspot (center tile)
            } else {
                TileTraffic {
                    core: Some(GenCfg {
                        write_fraction: 0.5,
                        seed: i as u64,
                        max_outstanding: 4,
                        ..GenCfg::narrow_probe(NodeId(4), 25)
                    }),
                    dma: Some(GenCfg {
                        write_fraction: 0.5,
                        seed: 20 + i as u64,
                        max_outstanding: 2,
                        ..GenCfg::dma_burst(NodeId(4), 6, false)
                    }),
                }
            }
        })
        .collect();
    let mut w = TiledWorkload::new(sys, profiles);
    assert!(w.run_to_completion(5_000_000), "hotspot deadlocked");
    assert!(w.protocol_ok());
    let t = &w.sys.nodes[4].target.stats;
    assert_eq!(t.reads_served + t.writes_served, 8 * (25 + 6) as u64 / 2 * 2);
    assert!(t.req_stall_cycles > 0, "backpressure must have engaged");
}

/// Zero-capacity configurations are rejected at construction.
#[test]
#[should_panic]
fn zero_buffer_depth_rejected() {
    let mut cfg = NocConfig::mesh(2, 1);
    cfg.in_buf_depth = 0;
    let _ = NocSystem::new(cfg);
}

/// Config loader rejects malformed files with useful errors.
#[test]
fn config_loader_failure_paths() {
    for bad in [
        "{",
        r#"{"mode": 42}"#,
        r#"{"mesh": {"width": 0}}"#,
        r#"{"router": {"in_buf_depth": 0}}"#,
    ] {
        let r = floonoc::config::noc_config_from_json(bad);
        if bad == r#"{"mode": 42}"# {
            // Non-string mode is ignored by the lenient getter; width 0
            // and depth 0 must hard-fail.
            continue;
        }
        assert!(r.is_err(), "accepted: {bad}");
    }
}
