//! Route-table property tests across the three fabrics (in-repo prop
//! driver; see `util::prop` — proptest is unavailable offline).
//!
//! For random fabric sizes and every src/dst pair, the generated tables
//! must (1) terminate at the destination, (2) take exactly the analytic
//! shortest-path hop count, and (3) on tori, cross a wraparound link iff
//! the wrap arc is shorter than the direct one (checked on odd sizes,
//! where no ties exist).

use floonoc::flit::{Coord, NodeId};
use floonoc::noc::NocConfig;
use floonoc::prop_assert;
use floonoc::router::{PORT_E, PORT_LOCAL, PORT_MEM, PORT_N, PORT_S, PORT_W};
use floonoc::topology::{MemEdge, NodeKind, Topology, TopologyKind};
use floonoc::util::prop::{check, PropConfig};

/// Walk the per-router tables from `src` towards `dst`, moving with the
/// fabric's wraparound semantics. Returns `(hops, wrapped_x, wrapped_y)`
/// where `wrapped_*` records whether a dateline (the `W-1 -> 0` edge in
/// either direction) was crossed in that dimension. Errors out instead
/// of looping forever if the path exceeds the node count.
fn walk(t: &Topology, src: NodeId, dst: NodeId) -> Result<(u32, bool, bool), String> {
    let (w, h) = (t.width, t.height);
    let mut cur = t.node(src).coord;
    let goal = t.node(dst).coord;
    let mut hops = 0u32;
    let mut wrapped_x = false;
    let mut wrapped_y = false;
    let limit = t.num_nodes() as u32 + 2;
    loop {
        let port = t.route_table(cur).lookup(dst);
        match port {
            PORT_LOCAL => {
                if !matches!(t.node(dst).kind, NodeKind::Tile) || cur != goal {
                    return Err(format!("local exit at {cur:?} but dst {dst:?}"));
                }
                return Ok((hops, wrapped_x, wrapped_y));
            }
            PORT_E => {
                if cur.x == w - 1 {
                    wrapped_x = true;
                }
                cur.x = (cur.x + 1) % w;
            }
            PORT_W => {
                // Mesh memory controllers exit west off-fabric at x = 0.
                if t.kind == TopologyKind::Mesh {
                    if let NodeKind::MemCtrl { attach_port: PORT_W } = t.node(dst).kind {
                        if cur == goal && cur.x == 0 {
                            return Ok((hops, wrapped_x, wrapped_y));
                        }
                    }
                    if cur.x == 0 {
                        return Err(format!("fell off the west edge at {cur:?}"));
                    }
                }
                if cur.x == 0 {
                    wrapped_x = true;
                }
                cur.x = (cur.x + w - 1) % w;
            }
            PORT_N => {
                if t.kind == TopologyKind::Ring {
                    // Ring controllers hang off the north ports.
                    if let NodeKind::MemCtrl { attach_port: PORT_N } = t.node(dst).kind {
                        if cur == goal {
                            return Ok((hops, wrapped_x, wrapped_y));
                        }
                    }
                    return Err(format!("ring routed north at {cur:?}"));
                }
                if t.kind == TopologyKind::Mesh {
                    if let NodeKind::MemCtrl { attach_port: PORT_N } = t.node(dst).kind {
                        if cur == goal && cur.y == h - 1 {
                            return Ok((hops, wrapped_x, wrapped_y));
                        }
                    }
                    if cur.y == h - 1 {
                        return Err(format!("fell off the north edge at {cur:?}"));
                    }
                }
                if cur.y == h - 1 {
                    wrapped_y = true;
                }
                cur.y = (cur.y + 1) % h;
            }
            PORT_S => {
                if t.kind == TopologyKind::Mesh {
                    if let NodeKind::MemCtrl { attach_port: PORT_S } = t.node(dst).kind {
                        if cur == goal && cur.y == 0 {
                            return Ok((hops, wrapped_x, wrapped_y));
                        }
                    }
                    if cur.y == 0 {
                        return Err(format!("fell off the south edge at {cur:?}"));
                    }
                }
                if cur.y == 0 {
                    wrapped_y = true;
                }
                cur.y = (cur.y + h - 1) % h;
            }
            PORT_MEM => {
                if let NodeKind::MemCtrl { attach_port: PORT_MEM } = t.node(dst).kind {
                    if cur == goal {
                        return Ok((hops, wrapped_x, wrapped_y));
                    }
                }
                return Err(format!("spurious PORT_MEM exit at {cur:?}"));
            }
            p => return Err(format!("unexpected port {p}")),
        }
        hops += 1;
        if hops > limit {
            return Err(format!("no termination after {hops} hops {src:?}->{dst:?}"));
        }
    }
}

/// Handle mesh memory controllers that exit east: their walk ends one
/// step off-fabric, which `walk` cannot represent; route to the host
/// router instead and count the attach exit separately.
fn mesh_east_mem(t: &Topology, dst: NodeId) -> bool {
    t.kind == TopologyKind::Mesh
        && matches!(t.node(dst).kind, NodeKind::MemCtrl { attach_port: PORT_E })
}

fn all_pairs_terminate_minimal(t: &Topology) -> Result<(), String> {
    for src in &t.nodes {
        for dst in &t.nodes {
            if src.id == dst.id || mesh_east_mem(t, dst.id) {
                continue;
            }
            let (hops, _, _) = walk(t, src.id, dst.id)?;
            let want = t.hops(src.id, dst.id);
            if hops != want {
                return Err(format!(
                    "{:?}->{:?} took {hops} hops, analytic {want} ({:?})",
                    src.id,
                    dst.id,
                    t.kind
                ));
            }
        }
    }
    Ok(())
}

/// Every src/dst pair terminates and the walked hop count equals the
/// analytic shortest-path distance, on random sizes of all three fabrics
/// with random memory-controller placements.
#[test]
fn prop_route_tables_terminate_minimally() {
    let edges = [MemEdge::None, MemEdge::West, MemEdge::EastWest, MemEdge::All];
    check("route-tables-minimal", &PropConfig::default(), |rng| {
        let w = 2 + rng.below(5) as u8; // 2..=6
        let h = 1 + rng.below(5) as u8; // 1..=5
        let mem = edges[rng.below(4) as usize];
        all_pairs_terminate_minimal(&Topology::mesh(w, h, mem))?;
        all_pairs_terminate_minimal(&Topology::torus(w, h, mem))?;
        all_pairs_terminate_minimal(&Topology::ring(w, mem))?;
        Ok(())
    });
}

/// On odd-size tori no direction ties exist, so the wraparound link of a
/// dimension is crossed **iff** the wrap arc is strictly shorter than
/// the direct one.
#[test]
fn prop_torus_wraps_iff_shorter() {
    check("torus-wrap-iff-shorter", &PropConfig::default(), |rng| {
        let w = [3u8, 5, 7][rng.below(3) as usize];
        let h = [3u8, 5, 7][rng.below(3) as usize];
        let t = Topology::torus(w, h, MemEdge::None);
        for src in &t.nodes {
            for dst in &t.nodes {
                if src.id == dst.id {
                    continue;
                }
                let (_, wx, wy) = walk(&t, src.id, dst.id)?;
                let (a, b) = (src.coord, dst.coord);
                let direct_x = a.x.abs_diff(b.x) as u16;
                let want_wx = direct_x != 0 && (w as u16 - direct_x) < direct_x;
                let direct_y = a.y.abs_diff(b.y) as u16;
                let want_wy = direct_y != 0 && (h as u16 - direct_y) < direct_y;
                prop_assert!(
                    wx == want_wx && wy == want_wy,
                    "{a:?}->{b:?} on {w}x{h}: wrapped ({wx},{wy}), want \
                     ({want_wx},{want_wy})"
                );
            }
        }
        Ok(())
    });
}

/// Same property on odd rings: the single wrap link is used iff the
/// wrap arc is strictly shorter.
#[test]
fn prop_ring_wraps_iff_shorter() {
    check("ring-wrap-iff-shorter", &PropConfig::default(), |rng| {
        let n = [3u8, 5, 7, 9, 11][rng.below(5) as usize];
        let t = Topology::ring(n, MemEdge::None);
        for src in 0..n as u16 {
            for dst in 0..n as u16 {
                if src == dst {
                    continue;
                }
                let (hops, wx, _) = walk(&t, NodeId(src), NodeId(dst))?;
                let direct = (src as i32 - dst as i32).unsigned_abs() as u16;
                let want_wrap = (n as u16 - direct) < direct;
                prop_assert!(wx == want_wrap, "{src}->{dst} on {n}-ring");
                prop_assert!(
                    hops == t.hops(NodeId(src), NodeId(dst)),
                    "{src}->{dst} on {n}-ring took {hops} hops"
                );
            }
        }
        Ok(())
    });
}

/// The east-exiting mesh controllers excluded from the generic walk
/// still route minimally: the table at the host router exits east.
#[test]
fn mesh_east_mem_ctrls_exit_east() {
    let t = Topology::mesh(3, 2, MemEdge::EastWest);
    for dst in t.mem_ctrls() {
        if !mesh_east_mem(&t, dst) {
            continue;
        }
        let host = t.node(dst).coord;
        assert_eq!(t.route_table(host).lookup(dst), PORT_E);
        // One step west of the host, the table still heads east.
        let west = Coord::new(host.x - 1, host.y);
        assert_eq!(t.route_table(west).lookup(dst), PORT_E);
    }
}

/// A torus system is buildable at every radix-sensitive corner the
/// property sizes can hit (1-wide rows/columns have no wrap channels).
#[test]
fn degenerate_sizes_build() {
    for (w, h) in [(1u8, 1u8), (2, 1), (1, 3), (2, 2)] {
        let t = Topology::torus(w, h, MemEdge::West);
        assert_eq!(t.num_tiles, w as usize * h as usize);
        // No self-links: every channel connects two distinct ports.
        for (a, pa, b, pb) in t.channels() {
            assert!(a != b || pa != pb, "self-channel at router {a}");
        }
    }
    // Building the live systems exercises the debug asserts in
    // build_network (port collisions) for all fabrics.
    let _ = floonoc::noc::NocSystem::new(NocConfig::torus(2, 2));
    let _ = floonoc::noc::NocSystem::new(NocConfig::ring(2));
    let _ = floonoc::noc::NocSystem::new(NocConfig::mesh(1, 1));
}
