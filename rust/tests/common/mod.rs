//! Shared integration-test support (included via `mod common;` from the
//! test binaries that need it — not a test target itself).

// Each including test binary uses a subset of these helpers; unused-item
// warnings in the other binaries are expected, not bugs.
#![allow(dead_code)]

use floonoc::cluster::TiledWorkload;
use floonoc::sim::SimMode;

/// Serialize every observable counter of a drained workload — total
/// cycles, per-network flit-conservation counters, per-link
/// delivered/stall/busy counters, per-router-per-port forwarding
/// counters, per-node target statistics and per-tile generator
/// completions and latency aggregates. Two runs are equivalent iff
/// their digests are **byte-identical**; any divergence — a component
/// skipped while it had work, a wake edge firing a cycle early or late,
/// VC plumbing leaking into a 1-VC configuration — shows up as a
/// counter mismatch somewhere in this string.
///
/// Shared by `gated_equivalence.rs` (gated-vs-dense differential) and
/// `vc_deadlock.rs` (1-VC non-regression and wrap-saturation
/// differential) so both suites pin the *same* notion of equivalence.
pub fn digest(w: &mut TiledWorkload) -> String {
    use std::fmt::Write;
    let mut d = String::new();
    writeln!(d, "cycles={}", w.sys.now).unwrap();
    for (n, c) in w.sys.counters.iter().enumerate() {
        writeln!(d, "net{n} injected={} ejected={}", c.injected, c.ejected).unwrap();
    }
    for (n, net) in w.sys.nets.iter().enumerate() {
        for (lid, l) in net.links.iter().enumerate() {
            // Skip never-touched links to keep the digest readable; a
            // link touched in one mode but not the other still diverges
            // (its line exists on one side only).
            if l.delivered == 0 && l.busy_cycles == 0 {
                continue;
            }
            writeln!(
                d,
                "net{n} link{lid} delivered={} stall={} busy={}",
                l.delivered, l.stall_cycles, l.busy_cycles
            )
            .unwrap();
        }
        for (rid, r) in net.routers.iter().enumerate() {
            if r.forwarded == 0 {
                continue;
            }
            let per_port: Vec<String> = (0..r.cfg.ports)
                .map(|p| r.forwarded_on(p).to_string())
                .collect();
            writeln!(
                d,
                "net{n} router{rid} forwarded={} active={} ports=[{}]",
                r.forwarded,
                r.active_cycles,
                per_port.join(",")
            )
            .unwrap();
        }
    }
    for (idx, node) in w.sys.nodes.iter().enumerate() {
        let s = &node.target.stats;
        writeln!(
            d,
            "node{idx} reads={} writes={} atomics={} req_stalls={}",
            s.reads_served, s.writes_served, s.atomics_served, s.req_stall_cycles
        )
        .unwrap();
    }
    for t in &mut w.tiles {
        for (tag, g) in [
            ("core", t.core_gen.as_mut()),
            ("dma", t.dma_gen.as_mut()),
        ] {
            let Some(g) = g else { continue };
            writeln!(
                d,
                "tile{} {tag} issued={} completed={} lat_count={} lat_mean={:.6} lat_min={} lat_max={} lat_p50={}",
                t.node.0,
                g.issued,
                g.completed,
                g.latencies.count(),
                g.latencies.mean(),
                g.latencies.min(),
                g.latencies.max(),
                g.latencies.p50(),
            )
            .unwrap();
        }
    }
    d
}

/// The differential runner: build the same seeded workload under
/// [`SimMode::Dense`], [`SimMode::Gated`] and [`SimMode::Event`], run
/// each to completion, and assert all three digests are
/// **byte-identical**. Dense is the reference sweep, gated skips by
/// activity, event additionally fast-forwards the clock over provably
/// idle stretches — none of which may change a single counter.
///
/// On top of the mode axis, every mode is re-run on the sharded engine
/// at 2 and 4 shards (`NocConfig::shards`; the engine clamps to the
/// fabric's strip dimension) and each sharded digest must match the
/// dense reference byte for byte too — the determinism contract of
/// `floonoc::noc::sharded` is that thread count is unobservable.
///
/// Also pins the cycle bookkeeping: gated/dense must never skip
/// (`skipped_cycles == 0`), and under event every cycle is either
/// stepped or skipped (`stepped + skipped == now`).
pub fn assert_modes_equivalent<F>(label: &str, max_cycles: u64, mk: F)
where
    F: Fn(SimMode) -> TiledWorkload,
{
    let run = |mode: SimMode, shards: usize| {
        let mut w = mk(mode);
        w.sys.cfg.shards = shards;
        assert!(
            w.run_to_completion(max_cycles),
            "{label}/{mode:?}/shards={shards} must drain"
        );
        assert!(w.protocol_ok(), "{label}/{mode:?}/shards={shards} protocol clean");
        if mode == SimMode::Event {
            assert_eq!(
                w.sys.stepped_cycles + w.sys.skipped_cycles,
                w.sys.now,
                "{label}/event/shards={shards}: stepped + skipped must reconcile with the clock"
            );
        } else {
            assert_eq!(
                w.sys.skipped_cycles, 0,
                "{label}/{mode:?}/shards={shards}: only event mode may fast-forward"
            );
        }
        digest(&mut w)
    };
    let dense = run(SimMode::Dense, 1);
    let gated = run(SimMode::Gated, 1);
    let event = run(SimMode::Event, 1);
    assert!(
        gated == dense,
        "gated != dense for {label}\n--- gated ---\n{gated}\n--- dense ---\n{dense}"
    );
    assert!(
        event == dense,
        "event != dense for {label}\n--- event ---\n{event}\n--- dense ---\n{dense}"
    );
    for shards in [2, 4] {
        for mode in [SimMode::Dense, SimMode::Gated, SimMode::Event] {
            let sharded = run(mode, shards);
            assert!(
                sharded == dense,
                "{shards}-shard {mode:?} != serial dense for {label}\n\
                 --- sharded ---\n{sharded}\n--- dense ---\n{dense}"
            );
        }
    }
}

/// [`assert_modes_equivalent`] for workloads that **never drain**
/// (saturated scenarios with `num_txns: u64::MAX`): run every mode ×
/// shard-count combination to a fixed cycle `horizon` instead of to
/// completion, then require all digests byte-identical to the serial
/// dense reference. `run_to_completion` must report *not* drained and
/// the clock must land exactly on the horizon — a saturated workload
/// stopping early would mean the equivalence compared fewer cycles than
/// advertised.
pub fn assert_modes_equivalent_bounded<F>(label: &str, horizon: u64, mk: F)
where
    F: Fn(SimMode) -> TiledWorkload,
{
    let run = |mode: SimMode, shards: usize| {
        let mut w = mk(mode);
        w.sys.cfg.shards = shards;
        assert!(
            !w.run_to_completion(horizon),
            "{label}/{mode:?}/shards={shards}: a saturated workload must not drain"
        );
        assert_eq!(
            w.sys.now, horizon,
            "{label}/{mode:?}/shards={shards}: clock must land exactly on the horizon"
        );
        assert!(w.protocol_ok(), "{label}/{mode:?}/shards={shards} protocol clean");
        digest(&mut w)
    };
    let dense = run(SimMode::Dense, 1);
    for shards in [1, 2, 4] {
        for mode in [SimMode::Dense, SimMode::Gated, SimMode::Event] {
            if mode == SimMode::Dense && shards == 1 {
                continue; // the reference itself
            }
            let other = run(mode, shards);
            assert!(
                other == dense,
                "{shards}-shard {mode:?} != serial dense for {label}\n\
                 --- candidate ---\n{other}\n--- dense ---\n{dense}"
            );
        }
    }
}
