//! AXI4-compliance integration: the ordering monitor is the oracle; the
//! full system (NI + routers + memories) must keep it clean under
//! adversarial workloads designed to create reordering.

use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::flit::NodeId;
use floonoc::noc::{NocConfig, NocSystem};
use floonoc::traffic::{GenCfg, Pattern};

fn run_checked(cfg: NocConfig, profiles: Vec<TileTraffic>, max: u64) -> TiledWorkload {
    let sys = NocSystem::new(cfg);
    let mut w = TiledWorkload::new(sys, profiles);
    assert!(w.run_to_completion(max), "workload stalled");
    assert!(w.protocol_ok(), "AXI protocol violations");
    w
}

/// Single-ID traffic to mixed-distance destinations: the hardest case for
/// same-ID ordering (responses naturally arrive out of order).
#[test]
fn single_id_mixed_distance_reads() {
    let mut profiles: Vec<TileTraffic> = (0..6).map(|_| TileTraffic::idle()).collect();
    profiles[0].core = Some(GenCfg {
        pattern: Pattern::UniformTiles,
        ids: 1,
        max_outstanding: 4,
        num_txns: 100,
        seed: 7,
        ..GenCfg::narrow_probe(NodeId(1), 100)
    });
    run_checked(NocConfig::mesh(6, 1), profiles, 2_000_000);
}

/// Same for wide-bus bursts (multi-beat responses reordering).
#[test]
fn single_id_mixed_distance_bursts() {
    let mut profiles: Vec<TileTraffic> = (0..6).map(|_| TileTraffic::idle()).collect();
    profiles[0].dma = Some(GenCfg {
        pattern: Pattern::UniformTiles,
        ids: 1,
        max_outstanding: 6,
        num_txns: 40,
        seed: 13,
        ..GenCfg::dma_burst(NodeId(1), 40, false)
    });
    run_checked(NocConfig::mesh(6, 1), profiles, 2_000_000);
}

/// Mixed reads and writes on every ID from every tile simultaneously.
#[test]
fn full_mesh_mixed_read_write() {
    let profiles: Vec<TileTraffic> = (0..9)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                write_fraction: 0.5,
                ids: 4,
                max_outstanding: 8,
                seed: i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 50)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                write_fraction: 0.5,
                ids: 4,
                max_outstanding: 4,
                seed: 50 + i as u64,
                ..GenCfg::dma_burst(NodeId(0), 12, false)
            }),
        })
        .collect();
    let w = run_checked(NocConfig::mesh(3, 3), profiles, 4_000_000);
    // Every tile completed everything.
    for t in &w.tiles {
        assert!(t.core_gen.as_ref().unwrap().monitor.quiescent());
        assert!(t.dma_gen.as_ref().unwrap().monitor.quiescent());
    }
}

/// Write-after-write to the same target from many sources: W-burst
/// reassembly at the target must pair AWs and bursts correctly.
#[test]
fn many_writers_one_target() {
    let mut profiles: Vec<TileTraffic> = (0..8).map(|_| TileTraffic::idle()).collect();
    for (i, p) in profiles.iter_mut().enumerate().skip(1) {
        p.dma = Some(GenCfg {
            seed: i as u64,
            max_outstanding: 4,
            ..GenCfg::dma_burst(NodeId(0), 10, true)
        });
    }
    let w = run_checked(NocConfig::mesh(4, 2), profiles, 2_000_000);
    assert_eq!(
        w.sys.nodes[0].target.stats.writes_served,
        7 * 10,
        "all write bursts reassembled and served"
    );
}

/// Tiny per-ID depth forces continuous head-of-ID flow control.
#[test]
fn per_id_depth_one() {
    let mut cfg = NocConfig::mesh(3, 1);
    cfg.narrow_init.per_id_depth = 1;
    let mut profiles: Vec<TileTraffic> = (0..3).map(|_| TileTraffic::idle()).collect();
    profiles[0].core = Some(GenCfg {
        pattern: Pattern::UniformTiles,
        ids: 2,
        max_outstanding: 2,
        seed: 3,
        ..GenCfg::narrow_probe(NodeId(1), 60)
    });
    run_checked(cfg, profiles, 2_000_000);
}

/// Different IDs may complete out of order (the freedom the ROB exploits)
/// — verified implicitly by the monitor accepting interleaved
/// completions across IDs in all tests above; here we assert the system
/// actually used that freedom under mixed-distance multi-ID traffic.
#[test]
fn cross_id_out_of_order_happens() {
    let mut profiles: Vec<TileTraffic> = (0..6).map(|_| TileTraffic::idle()).collect();
    profiles[0].core = Some(GenCfg {
        pattern: Pattern::UniformTiles,
        ids: 4,
        max_outstanding: 8,
        seed: 11,
        ..GenCfg::narrow_probe(NodeId(1), 80)
    });
    let w = run_checked(NocConfig::mesh(6, 1), profiles, 2_000_000);
    let (bypassed, buffered) = w.sys.nodes[0]
        .narrow
        .as_ref()
        .unwrap()
        .reorder_stats();
    assert!(bypassed > 0);
    // Multi-ID + mixed distance: some responses must have needed the ROB.
    assert!(buffered > 0, "no reordering pressure generated");
}
