//! Property-based invariant tests (in-repo prop driver; see
//! `util::prop` — proptest is unavailable offline).

use floonoc::axi::{AxReq, Burst};
use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::flit::NodeId;
use floonoc::ni::rob::RobAllocator;
use floonoc::noc::{LinkMode, NocConfig, NocSystem};
use floonoc::prop_assert;
use floonoc::traffic::{GenCfg, Pattern};
use floonoc::util::prop::{check, PropConfig};
use floonoc::util::rng::Rng;

fn small_cfg() -> PropConfig {
    // System-level properties run fewer, heavier cases.
    let mut c = PropConfig::default();
    c.cases = c.cases.min(24);
    c
}

/// ROB allocator: random alloc/release interleavings never violate the
/// free-list invariants, never double-grant, and always recover all slots.
#[test]
fn prop_rob_allocator_invariants() {
    check("rob-invariants", &PropConfig::default(), |rng| {
        let slots = 8 + rng.below(120) as u32;
        let mut rob = RobAllocator::new(slots);
        let mut live: Vec<floonoc::ni::rob::RobGrant> = Vec::new();
        for _ in 0..200 {
            if rng.chance(0.6) || live.is_empty() {
                let len = 1 + rng.below(16.min(slots as u64)) as u32;
                if let Some(g) = rob.alloc(len) {
                    // No overlap with any live grant.
                    for l in &live {
                        let disjoint = g.base + g.len <= l.base || l.base + l.len <= g.base;
                        prop_assert!(disjoint, "grant {g:?} overlaps {l:?}");
                    }
                    live.push(g);
                }
            } else {
                let idx = rng.index(live.len());
                let g = live.swap_remove(idx);
                rob.release(g);
            }
            rob.check_invariants().map_err(|e| e)?;
        }
        for g in live.drain(..) {
            rob.release(g);
        }
        prop_assert!(
            rob.free_slots() == slots,
            "leaked slots: {} of {slots} free",
            rob.free_slots()
        );
        Ok(())
    });
}

/// AXI burst arithmetic: beat addresses stay inside the burst footprint
/// and WRAP bursts stay inside their aligned container.
#[test]
fn prop_burst_addresses_bounded() {
    check("burst-addresses", &PropConfig::default(), |rng| {
        let size = rng.below(4) as u8 + 2; // 4..=32 B beats
        let burst = *rng.choose(&[Burst::Incr, Burst::Wrap, Burst::Fixed]);
        let len = match burst {
            Burst::Wrap => *rng.choose(&[1u8, 3, 7, 15]),
            _ => rng.below(16) as u8,
        };
        let align = 1u64 << size;
        let addr = (rng.below(1 << 20) / align) * align + (1 << 20);
        let req = AxReq {
            id: 0,
            addr,
            len,
            size,
            burst,
            atop: false,
        };
        if !req.is_legal(64) {
            return Ok(()); // property only constrains legal bursts
        }
        let total = req.total_bytes() as u64;
        for i in 0..req.beats() {
            let a = req.beat_addr(i);
            match burst {
                Burst::Fixed => prop_assert!(a == addr, "fixed moved"),
                Burst::Incr => prop_assert!(
                    a >= addr && a + align <= addr + total,
                    "incr beat {i} out of range"
                ),
                Burst::Wrap => {
                    let container = total;
                    let base = addr & !(container - 1);
                    prop_assert!(
                        a >= base && a + align <= base + container,
                        "wrap beat {i} escaped container"
                    );
                }
            }
        }
        Ok(())
    });
}

/// End-to-end delivery: ANY random workload on ANY small mesh in BOTH
/// link modes completes with clean protocol monitors and conserved flits.
#[test]
fn prop_random_workloads_complete() {
    check("random-workloads", &small_cfg(), |rng| {
        let w = 1 + rng.below(3) as u8;
        let h = 1 + rng.below(3) as u8;
        if (w, h) == (1, 1) {
            return Ok(());
        }
        let mode = if rng.chance(0.5) {
            LinkMode::NarrowWide
        } else {
            LinkMode::WideOnly
        };
        let mut cfg = NocConfig::mesh(w, h);
        cfg.mode = mode;
        cfg.in_buf_depth = 1 + rng.below(3) as usize;
        cfg.output_reg = rng.chance(0.5);
        let sys = NocSystem::new(cfg);
        let tiles = sys.topo.num_tiles;
        let profiles: Vec<TileTraffic> = (0..tiles)
            .map(|i| TileTraffic {
                core: rng.chance(0.8).then(|| GenCfg {
                    pattern: Pattern::UniformTiles,
                    write_fraction: rng.f64() * 0.6,
                    max_outstanding: 1 + rng.below(8) as u32,
                    num_txns: 5 + rng.below(20),
                    seed: rng.next_u64(),
                    ..GenCfg::narrow_probe(NodeId(0), 1)
                }),
                dma: rng.chance(0.6).then(|| GenCfg {
                    pattern: Pattern::UniformTiles,
                    write_fraction: rng.f64(),
                    burst_len: *rng.choose(&[0u8, 3, 7, 15]),
                    max_outstanding: 1 + rng.below(4) as u32,
                    num_txns: 2 + rng.below(6),
                    seed: rng.next_u64(),
                    ..GenCfg::dma_burst(NodeId(0), 1, false)
                }),
            })
            .collect();
        let mut wl = TiledWorkload::new(sys, profiles);
        prop_assert!(
            wl.run_to_completion(3_000_000),
            "stalled: {w}x{h} {mode:?}"
        );
        prop_assert!(wl.protocol_ok(), "protocol violation: {w}x{h} {mode:?}");
        for c in &wl.sys.counters {
            prop_assert!(
                c.injected == c.ejected,
                "flits lost: {} vs {}",
                c.injected,
                c.ejected
            );
        }
        Ok(())
    });
}

/// Determinism: the same seed gives byte-identical results.
#[test]
fn prop_simulation_deterministic() {
    check("determinism", &small_cfg(), |rng| {
        let seed = rng.next_u64();
        let run = |seed: u64| -> (u64, f64) {
            let sys = NocSystem::new(NocConfig::mesh(2, 2));
            let profiles: Vec<TileTraffic> = (0..4)
                .map(|i| TileTraffic {
                    core: Some(GenCfg {
                        pattern: Pattern::UniformTiles,
                        seed: seed ^ i as u64,
                        ..GenCfg::narrow_probe(NodeId(0), 20)
                    }),
                    dma: None,
                })
                .collect();
            let mut w = TiledWorkload::new(sys, profiles);
            assert!(w.run_to_completion(1_000_000));
            let lat = w.tiles[0].core_gen.as_mut().unwrap().latencies.mean();
            (w.sys.now, lat)
        };
        let a = run(seed);
        let b = run(seed);
        prop_assert!(a == b, "nondeterministic: {a:?} vs {b:?}");
        Ok(())
    });
}

/// The analytical model conserves hops for random traffic matrices.
#[test]
fn prop_analytical_hop_conservation() {
    check("hop-conservation", &PropConfig::default(), |rng| {
        let n = 2 + rng.index(5);
        let nodes = n * n;
        let mut t = vec![vec![0.0; nodes]; nodes];
        for row in t.iter_mut() {
            for v in row.iter_mut() {
                *v = if rng.chance(0.3) { rng.f64() } else { 0.0 };
            }
        }
        for (s, row) in t.iter_mut().enumerate() {
            row[s] = 0.0;
        }
        let loads = floonoc::dse::link_loads(&t, n);
        let total: f64 = loads.iter().flatten().flatten().sum();
        let mut want = 0.0;
        for s in 0..nodes {
            for d in 0..nodes {
                let (sx, sy) = ((s % n) as i64, (s / n) as i64);
                let (dx, dy) = ((d % n) as i64, (d / n) as i64);
                want += t[s][d] * ((sx - dx).abs() + (sy - dy).abs()) as f64;
            }
        }
        prop_assert!(
            (total - want).abs() < 1e-6,
            "hop conservation broke: {total} vs {want}"
        );
        Ok(())
    });
}

/// PRNG sanity as a property: `below(n)` is always `< n`.
#[test]
fn prop_rng_below_bound() {
    check("rng-below", &PropConfig::default(), |rng| {
        let bound = 1 + rng.next_u64() % 10_000;
        let mut r = Rng::new(rng.next_u64());
        for _ in 0..100 {
            prop_assert!(r.below(bound) < bound, "out of range");
        }
        Ok(())
    });
}

/// JSON roundtrip: any value we can build serializes and reparses
/// identically (S2 in the DESIGN inventory).
#[test]
fn prop_json_roundtrip() {
    use floonoc::util::json::Json;
    fn gen_value(rng: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.below(1_000_000) as f64) - 500_000.0),
            3 => {
                let n = rng.below(12) as usize;
                Json::Str(
                    (0..n)
                        .map(|_| (b'a' + rng.below(26) as u8) as char)
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..rng.below(4)).map(|_| gen_value(rng, depth - 1)).collect(),
            ),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json-roundtrip", &PropConfig::default(), |rng| {
        let v = gen_value(rng, 3);
        let text = v.to_string();
        let back = floonoc::util::json::Json::parse(&text)
            .map_err(|e| format!("reparse failed: {e} for {text}"))?;
        prop_assert!(back == v, "roundtrip mismatch: {text}");
        Ok(())
    });
}

/// Link handshake property (S5): under random offer/consume schedules a
/// link never drops, duplicates, or reorders flits, with or without
/// pipeline stages.
#[test]
fn prop_link_handshake_lossless() {
    use floonoc::axi::{AxReq, Burst};
    use floonoc::flit::{FlooFlit, Header, NodeId, Payload};
    use floonoc::sim::Link;
    fn mk(tag: u32) -> FlooFlit {
        FlooFlit::new(
            Header {
                dst: NodeId(0),
                src: NodeId(0),
                rob_idx: tag,
                rob_req: false,
                atomic: false,
                last: true,
            },
            Payload::NarrowAr(AxReq {
                id: 0,
                addr: 0,
                len: 0,
                size: 3,
                burst: Burst::Incr,
                atop: false,
            }),
            0,
        )
    }
    check("link-lossless", &PropConfig::default(), |rng| {
        let depth = 1 + rng.below(4) as usize;
        let stages = rng.below(3) as usize;
        let mut link: Link<FlooFlit> = Link::with_pipeline(depth, stages);
        let total = 50 + rng.below(100) as u32;
        let mut sent = 0u32;
        let mut received = Vec::new();
        let mut budget = 0;
        while (received.len() as u32) < total {
            if sent < total && rng.chance(0.7) && link.can_offer() {
                link.offer(mk(sent));
                sent += 1;
            }
            link.deliver();
            if rng.chance(0.6) {
                if let Some(f) = link.pop() {
                    received.push(f.header.rob_idx);
                }
            }
            budget += 1;
            prop_assert!(budget < 100_000, "link wedged");
        }
        let want: Vec<u32> = (0..total).collect();
        prop_assert!(received == want, "reorder/loss: got {received:?}");
        prop_assert!(link.is_idle(), "flits left behind");
        Ok(())
    });
}

/// Virtual-channel lane property (S5b): under random per-lane offer and
/// per-lane consume schedules, a multi-VC link never drops, duplicates,
/// or reorders flits *within a lane*, and a congested lane never blocks
/// the others (the stall-isolation invariant dateline deadlock freedom
/// rests on — see docs/deadlock.md).
#[test]
fn prop_vc_link_lanes_independent_and_lossless() {
    use floonoc::sim::Link;
    check("vc-link-lanes", &PropConfig::default(), |rng| {
        let vcs = 2 + rng.below(2) as usize; // 2 or 3 lanes
        let depth = 1 + rng.below(4) as usize;
        let stages = rng.below(2) as usize;
        let mut link: Link<(usize, u32)> = Link::with_vcs(depth * vcs, vcs, stages);
        let per_lane = 30 + rng.below(40) as u32;
        let mut sent = vec![0u32; vcs];
        let mut received: Vec<Vec<u32>> = vec![Vec::new(); vcs];
        // Lane `vcs - 1` is throttled hard on the consume side; the other
        // lanes must still drain to completion long before the budget.
        let mut budget = 0;
        while received.iter().take(vcs - 1).any(|r| (r.len() as u32) < per_lane) {
            // At most one offer per cycle across all lanes: the physical
            // channel's bandwidth, as granted by the router switch.
            let v = rng.below(vcs as u64) as usize;
            if sent[v] < per_lane && rng.chance(0.8) && link.can_offer_vc(v) {
                link.offer_vc(v, (v, sent[v]));
                sent[v] += 1;
            }
            link.deliver();
            for v in 0..vcs {
                let throttled = v == vcs - 1 && !rng.chance(0.05);
                if !throttled && rng.chance(0.7) {
                    if let Some((lane, tag)) = link.pop_vc(v) {
                        prop_assert!(lane == v, "flit crossed lanes: {lane} on {v}");
                        received[v].push(tag);
                    }
                }
            }
            budget += 1;
            prop_assert!(budget < 200_000, "open lanes wedged behind throttled lane");
        }
        for (v, r) in received.iter().enumerate().take(vcs - 1) {
            let want: Vec<u32> = (0..per_lane).collect();
            prop_assert!(r == &want, "lane {v} reorder/loss: got {r:?}");
        }
        Ok(())
    });
}

/// Trace record/replay determinism: replaying a recorded random workload
/// reproduces the same completion counts.
#[test]
fn prop_trace_replay_consistent() {
    use floonoc::traffic::trace::{TraceEvent, TraceRecorder, TraceWorkload};
    use floonoc::topology::TILE_SPAN;
    check("trace-replay", &small_cfg(), |rng| {
        let n_events = 3 + rng.below(12);
        let events: Vec<TraceEvent> = (0..n_events)
            .map(|i| {
                let src = rng.below(2) as u16;
                let dst = 1 - src;
                TraceEvent {
                    cycle: i * rng.below(8),
                    src: NodeId(src),
                    dst: NodeId(dst),
                    bus: if rng.chance(0.5) {
                        floonoc::flit::BusKind::Wide
                    } else {
                        floonoc::flit::BusKind::Narrow
                    },
                    is_write: rng.chance(0.5),
                    id: rng.below(4) as u16,
                    len: if rng.chance(0.5) { 15 } else { 0 },
                    size: 3,
                    addr: dst as u64 * TILE_SPAN + rng.below(1024) * 128,
                }
            })
            .collect();
        // Serialize + reload (exercises the file format too).
        let rec = TraceRecorder { events };
        let mut buf = Vec::new();
        rec.write_to(&mut buf).map_err(|e| e.to_string())?;
        let reloaded = TraceRecorder::read_from(&buf[..]).map_err(|e| e.to_string())?;
        let run = |events: Vec<TraceEvent>| -> (u64, u64, u64) {
            let mut sys = NocSystem::new(NocConfig::mesh(2, 1));
            let mut w = TraceWorkload::new(events);
            for _ in 0..200_000 {
                sys.step();
                w.step(&mut sys);
                if w.done_issuing() && sys.is_idle() {
                    break;
                }
            }
            (w.issued, w.completed_reads, w.completed_writes)
        };
        let a = run(rec.events.clone());
        let b = run(reloaded.events);
        prop_assert!(a == b, "replay diverged: {a:?} vs {b:?}");
        prop_assert!(a.0 == n_events, "not all issued: {a:?}");
        Ok(())
    });
}
