//! Dateline virtual channels: wrap-fabric saturation without deadlock,
//! and byte-identical 1-VC mesh behavior.
//!
//! The first half drives **wide-burst uniform-random traffic at
//! saturation** — multi-flit wormhole packets, full default outstanding
//! budgets, every flow free to cross the wraparound links — on torus and
//! ring fabrics. Before dateline VCs this was exactly the cyclic-wait
//! configuration `docs/topologies.md` warned about; these tests pin that
//! it now runs to completion with continuous forward progress (a
//! stalled-cycle watchdog would flat-line on any wormhole deadlock long
//! before the cycle budget, see `TiledWorkload::run_with_watchdog`).
//!
//! The second half pins the non-regression side of the feature: a mesh
//! built with an explicit `vcs = 1` produces **byte-identical stats
//! digests** to the default mesh configuration — in both `SimMode`s —
//! using the same digest instrument as `tests/gated_equivalence.rs`.
//! The 1-VC code path *is* the pre-VC router (single lane, single lock
//! slot, same arbitration order), and this test fails if any VC
//! plumbing leaks into it.

use floonoc::cluster::{TileTraffic, TiledWorkload};
use floonoc::flit::NodeId;
use floonoc::noc::{NocConfig, NocSystem};
use floonoc::sim::SimMode;
use floonoc::topology::TopologyKind;
use floonoc::traffic::{GenCfg, Pattern};

mod common;
use common::digest;

/// Cycles of zero ejection progress that count as a seizure. Legitimate
/// quiet gaps in a saturated workload are bounded by memory latency plus
/// one burst drain — hundreds of cycles; 25k is an order of magnitude of
/// slack above that and still trips within a second on a real deadlock.
const STALL_WINDOW: u64 = 25_000;

/// Saturating wide-burst + narrow uniform traffic on every tile: the
/// full default outstanding budgets (`dma_burst`: 8 wide bursts in
/// flight, 16 beats each; `narrow_probe`: 4 narrow reads), no
/// single-hop restriction, no budget caps.
fn wrap_saturation_workload(cfg: NocConfig, wide_txns: u64) -> TiledWorkload {
    let sys = NocSystem::new(cfg);
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: 2 * wide_txns,
                seed: 0xDEAD + i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 2 * wide_txns)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: wide_txns,
                burst_len: 15,
                seed: 0xD0A7 + i as u64,
                ..GenCfg::dma_burst(NodeId(0), wide_txns, false)
            }),
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

/// Run a wrap-saturation workload to completion under the watchdog and
/// check protocol cleanliness.
fn assert_drains(cfg: NocConfig, wide_txns: u64, label: &str) {
    let mut w = wrap_saturation_workload(cfg, wide_txns);
    match w.run_with_watchdog(5_000_000, STALL_WINDOW) {
        Ok(true) => {}
        Ok(false) => panic!("{label}: cycle budget exhausted while still progressing"),
        Err(at) => panic!(
            "{label}: watchdog tripped — no progress since cycle {at} (deadlock)\n{}",
            w.stall_analysis()
        ),
    }
    assert!(w.protocol_ok(), "{label}: AXI protocol violations");
    let wide_done: u64 = w
        .tiles
        .iter()
        .map(|t| t.dma_gen.as_ref().unwrap().completed)
        .sum();
    assert_eq!(
        wide_done,
        w.tiles.len() as u64 * wide_txns,
        "{label}: every wide burst must complete"
    );
}

/// 4×4 torus at saturation: every row and column is a closed ring, and
/// uniform traffic holds wormholes across the datelines continuously.
#[test]
fn torus_4x4_wide_uniform_saturation_drains() {
    assert_drains(NocConfig::torus(4, 4), 6, "torus 4x4");
}

/// 8×8 torus: longer rings, more simultaneous wrap-crossing wormholes,
/// deeper cyclic-dependency potential. Fewer bursts per tile keep the
/// test CI-sized; the stress is concurrency, not volume.
#[test]
fn torus_8x8_wide_uniform_saturation_drains() {
    assert_drains(NocConfig::torus(8, 8), 3, "torus 8x8");
}

/// 8-node ring: the smallest fabric where every uniform flow contends
/// for the same two directions and half of the flows wrap.
#[test]
fn ring_8_wide_uniform_saturation_drains() {
    assert_drains(NocConfig::ring(8), 6, "ring 8");
}

/// Tornado on a torus is the adversarial case for the dateline: every
/// flow travels the diameter, so the wrap links carry half of *all*
/// traffic — saturate it with wide bursts too.
#[test]
fn torus_4x4_wide_tornado_saturation_drains() {
    let sys = NocSystem::new(NocConfig::torus(4, 4));
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: None,
            dma: Some(GenCfg {
                pattern: Pattern::Tornado,
                num_txns: 6,
                burst_len: 15,
                seed: 0x70AD + i as u64,
                ..GenCfg::dma_burst(NodeId(0), 6, false)
            }),
        })
        .collect();
    let mut w = TiledWorkload::new(sys, profiles);
    match w.run_with_watchdog(5_000_000, STALL_WINDOW) {
        Ok(true) => {}
        Err(at) => panic!(
            "torus tornado: watchdog tripped at cycle {at}\n{}",
            w.stall_analysis()
        ),
        other => panic!("torus tornado: {other:?}"),
    }
    assert!(w.protocol_ok());
}

// ---------------------------------------------------------------------
// 1-VC digest equivalence: the VC-aware stack with vcs = 1 must be the
// pre-VC simulator, byte for byte, in both step-loop modes.
// ---------------------------------------------------------------------

/// The gated_equivalence baseline workload, bound to an explicit config:
/// seeded narrow traffic in the pattern under test plus uniform-random
/// wide DMA bursts on a 3×3 fabric (same geometry, seeds, and burst
/// shapes as `tests/gated_equivalence.rs`).
fn baseline_workload(cfg: NocConfig, pattern: Pattern) -> TiledWorkload {
    let sys = NocSystem::new(cfg);
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| TileTraffic {
            core: Some(GenCfg {
                pattern,
                num_txns: 12,
                seed: 0xBEEF + i as u64,
                ..GenCfg::narrow_probe(NodeId(0), 12)
            }),
            dma: Some(GenCfg {
                pattern: Pattern::UniformTiles,
                num_txns: 3,
                burst_len: 7,
                seed: 0xD0A + i as u64,
                ..GenCfg::dma_burst(NodeId(0), 3, false)
            }),
        })
        .collect();
    TiledWorkload::new(sys, profiles)
}

fn run_digest(cfg: NocConfig, pattern: Pattern) -> String {
    let mut w = baseline_workload(cfg, pattern);
    assert!(w.run_to_completion(2_000_000), "baseline workload must drain");
    assert!(w.protocol_ok());
    digest(&mut w)
}

/// The 1-VC mesh non-regression pin, in the strongest form expressible
/// without committed golden digests (none exist in-repo; the absolute
/// baseline is carried by the pinned 18-cycle zero-load and hop-count
/// values elsewhere). Three claims, per pattern and in **both**
/// step-loop modes:
///
/// 1. the mesh default is still `vcs = 1`, and an explicit
///    `.with_vcs(1)` is digest-identical to it (the knob's 1-VC path is
///    the default path, with deterministic digests);
/// 2. **no VC plumbing engages structurally**: every link of the
///    drained system carries exactly one lane, and every delivered flit
///    rode lane 0 (`lane_delivered(0) == delivered`) — a VC leak into
///    the 1-VC configuration cannot hide from this;
/// 3. the digest itself is the shared `gated_equivalence` instrument,
///    so these runs *are* that suite's current mesh baselines.
#[test]
fn one_vc_mesh_digests_match_pre_vc_baselines() {
    for pattern in [Pattern::UniformTiles, Pattern::Tornado, Pattern::NearestNeighbor] {
        for mode in [SimMode::Gated, SimMode::Dense] {
            let default_cfg = NocConfig::fabric(TopologyKind::Mesh, 3, 3).with_sim_mode(mode);
            assert_eq!(default_cfg.vcs, 1, "mesh default must stay VC-free");
            let explicit = default_cfg.clone().with_vcs(1);
            let mut w = baseline_workload(default_cfg, pattern);
            assert!(w.run_to_completion(2_000_000), "baseline workload must drain");
            assert!(w.protocol_ok());
            for net in &w.sys.nets {
                for l in &net.links {
                    assert_eq!(l.vcs(), 1, "a 1-VC mesh must build single-lane links");
                    assert_eq!(
                        l.lane_delivered(0),
                        l.delivered,
                        "every flit of a 1-VC mesh must ride lane 0"
                    );
                }
            }
            let a = digest(&mut w);
            let b = run_digest(explicit, pattern);
            assert!(
                a == b,
                "1-VC mesh digest diverged from baseline ({pattern:?}/{mode:?})\n--- default ---\n{a}\n--- vcs=1 ---\n{b}"
            );
        }
    }
}

/// The 2-VC torus and ring stay gated/dense byte-identical under the
/// wrap-saturation regime itself — the differential oracle applied to
/// the new machinery at its hardest operating point (per-lane wake
/// edges, VC locks, dateline switches).
#[test]
fn wrap_saturation_gated_equals_dense() {
    for kind in [TopologyKind::Torus, TopologyKind::Ring] {
        let run = |mode: SimMode| {
            let cfg = NocConfig::fabric(kind, 3, 3).with_sim_mode(mode);
            let mut w = wrap_saturation_workload(cfg, 3);
            assert!(w.run_to_completion(3_000_000), "{kind:?}/{mode:?} drains");
            digest(&mut w)
        };
        let gated = run(SimMode::Gated);
        let dense = run(SimMode::Dense);
        assert!(
            gated == dense,
            "{kind:?} wrap saturation gated != dense\n{gated}\n---\n{dense}"
        );
    }
}

/// Downgrading a wrap fabric to 1 VC still *builds* (the documented
/// pre-VC regime for single-flit traffic); single-beat narrow reads
/// cannot hold-and-wait and must complete as before. The static
/// verifier rejects this configuration (its CDG has a cycle, and wide
/// wormhole traffic *would* deadlock — `tests/verify_static.rs` pins
/// both sides), so the explicit escape hatch is required.
#[test]
fn torus_with_one_vc_still_serves_single_flit_traffic() {
    let sys = NocSystem::new(NocConfig::torus(4, 4).with_vcs(1).no_verify());
    let tiles = sys.topo.num_tiles;
    let profiles: Vec<TileTraffic> = (0..tiles)
        .map(|i| {
            let mut c = GenCfg::narrow_probe(NodeId(0), 8);
            c.pattern = Pattern::UniformTiles;
            c.max_outstanding = 2;
            c.seed = 0x1FC + i as u64;
            TileTraffic {
                core: Some(c),
                dma: None,
            }
        })
        .collect();
    let mut w = TiledWorkload::new(sys, profiles);
    assert!(w.run_to_completion(500_000));
    assert!(w.protocol_ok());
}
