//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment resolves crates only from the repository itself
//! (no registry), so this vendored shim provides the subset of the anyhow
//! API the workspace uses: [`Error`], [`Result`], the [`Context`]
//! extension trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`: that is what allows the blanket
//! `impl<E: std::error::Error> From<E> for Error` powering `?` conversions
//! without colliding with `From<Error> for Error`.

use std::fmt;

/// A string-backed error value. The real anyhow keeps the source chain
/// alive; this shim folds context into the message eagerly, which is
/// equivalent for display purposes ("context: cause").
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a result defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: gone");
    }

    #[test]
    fn with_context_is_lazy_and_option_works() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn fails(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(fails(3).unwrap(), 3);
        assert_eq!(fails(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(fails(7).unwrap_err().to_string(), "unlucky");
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("{}-{}", 1, 2).to_string(), "1-2");
    }

    #[test]
    fn context_on_anyhow_result_nests() {
        let r: Result<()> = Err(anyhow!("inner"));
        assert_eq!(r.context("outer").unwrap_err().to_string(), "outer: inner");
    }
}
